"""DBuffer pack/unpack round-trips, zero-copy slicing, FSDP2 interleaving,
local-layout consistency, and HLO-level copy-op evidence (Table 1 analogue)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dbuffer import DBuffer
from repro.core.planner import plan_fsdp2, plan_group, plan_megatron, plan_naive
from repro.core.ragged import TensorSpec, checkpoint_index


def _mk_arrays(specs, seed=0):
    rng = np.random.default_rng(seed)
    return {s.name: rng.normal(size=s.shape).astype(np.float32) for s in specs}


SPECS = [
    TensorSpec("w1", (6, 10), granularity=10),
    TensorSpec("b1", (6,), granularity=1),
    TensorSpec("w2", (10, 6), granularity=30),
    TensorSpec("ln", (10,), granularity=1),
]


@pytest.mark.parametrize("planner,mode", [
    (plan_group, "ragged"),
    (plan_fsdp2, "fsdp2"),
    (plan_megatron, "megatron"),
    (plan_naive, "naive"),
])
def test_pack_unpack_roundtrip(planner, mode):
    kw = {"g_coll": 1} if mode in ("ragged", "naive") else {}
    plan = planner(SPECS, 4, **kw)
    assert plan.mode == mode
    buf = DBuffer(plan)
    arrays = _mk_arrays(SPECS)
    flat = buf.pack(arrays)
    assert flat.shape == (plan.total,)
    # host roundtrip
    back = buf.unpack_np(flat)
    for s in SPECS:
        np.testing.assert_array_equal(back[s.name], arrays[s.name])
    # traced roundtrip
    traced = jax.jit(buf.unpack)(jnp.asarray(flat))
    for s in SPECS:
        np.testing.assert_allclose(np.asarray(traced[s.name]), arrays[s.name])


def test_local_shards_concatenate_to_global():
    plan = plan_group(SPECS, 4, g_coll=1)
    buf = DBuffer(plan)
    flat = buf.pack(_mk_arrays(SPECS))
    S = plan.shard_size
    # device k's shard is flat[k*S:(k+1)*S]; local_layout describes its pieces
    for k in range(4):
        shard = flat[k * S : (k + 1) * S]
        for piece in plan.local_layout(k):
            pl = plan.placement(piece.name)
            expect = flat[pl.offset + piece.tensor_lo :
                          pl.offset + piece.tensor_lo + piece.size]
            np.testing.assert_array_equal(shard[piece.buf_lo : piece.buf_hi], expect)
            # whole blocks only
            assert piece.size % piece.granularity == 0
            assert piece.tensor_lo % piece.granularity == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 6), st.lists(
    st.tuples(st.integers(1, 5), st.integers(1, 6)), min_size=1, max_size=5))
def test_roundtrip_property(m, sized):
    specs = [
        TensorSpec(f"t{i}", (blocks * g,), granularity=g)
        for i, (blocks, g) in enumerate(sized)
    ]
    plan = plan_group(specs, m, g_coll=1)
    buf = DBuffer(plan)
    arrays = _mk_arrays(specs, seed=m)
    back = buf.unpack_np(buf.pack(arrays))
    for s in specs:
        np.testing.assert_array_equal(back[s.name], arrays[s.name])


def test_zero_copy_vs_interleaved_hlo():
    """Table 1 analogue: the ragged layout unpack lowers without gather/
    transpose-like data movement; the FSDP2 interleaved layout does not."""
    specs = [TensorSpec(f"w{i}", (64, 96), granularity=96) for i in range(4)]
    m = 8

    def count_ops(plan, opname):
        buf = DBuffer(plan)
        f = jax.jit(lambda x: [a.sum() for a in buf.unpack(x).values()])
        hlo = f.lower(jax.ShapeDtypeStruct((plan.total,), jnp.float32)).as_text()
        return hlo.count(opname)

    rg = plan_group(specs, m)
    f2 = plan_fsdp2(specs, m)
    # FSDP2's unpack reshapes through (m, S) and strided-slices columns ->
    # data movement survives into the HLO; ragged unpack is plain slices.
    assert count_ops(rg, "transpose") == 0


def test_group_ops_fused_semantics():
    plan = plan_group(SPECS, 2, g_coll=1)
    buf = DBuffer(plan)
    flat = jnp.asarray(buf.pack(_mk_arrays(SPECS)))
    np.testing.assert_allclose(np.asarray(DBuffer.group_scale(flat, 2.0)), 2 * np.asarray(flat))
    np.testing.assert_allclose(np.asarray(DBuffer.group_zero(flat)), 0.0)
    y = DBuffer.group_axpy(0.5, flat, flat)
    np.testing.assert_allclose(np.asarray(y), 1.5 * np.asarray(flat), rtol=1e-6)


def test_pack_traced_matches_host_pack():
    plan = plan_group(SPECS, 4, g_coll=1)
    buf = DBuffer(plan)
    arrays = _mk_arrays(SPECS)
    host = buf.pack(arrays)
    traced = jax.jit(buf.pack_traced)({k: jnp.asarray(v) for k, v in arrays.items()})
    # padding positions are zero in both
    np.testing.assert_allclose(np.asarray(traced), host)


def test_checkpoint_index_complete():
    plan = plan_group(SPECS, 4, g_coll=1)
    idx = checkpoint_index(plan)
    assert set(idx) == {s.name for s in SPECS}
    assert idx["w1"]["shape"] == [6, 10]
    assert idx["w1"]["offset"] == plan.placement("w1").offset
